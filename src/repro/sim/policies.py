"""Stateless device baseline policies (DESIGN.md §8.2).

Each baseline is a triple of pure functions over an explicit state pytree,
so a full protocol run is one ``lax.scan`` and a multi-seed sweep is one
``vmap`` over PRNG keys — no Python objects, no host RNG:

    init(key)                          -> state
    decide(state, key, batch)          -> actions (S,) i32
    update(state, batch, a, r, mask)   -> state

``batch`` is the per-slice gather from :class:`DeviceReplayEnv` (x_emb,
x_feat, domain — context only; feedback stays in the engine). Semantics
mirror the host classes in ``repro.core.baselines``: greedy here is
bit-compatible with ``EmpiricalGreedy`` (decide from pre-slice statistics,
ties to the lowest index); random draws from the jax PRNG instead of
numpy's, so it matches the host loop in distribution, not samples.
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp


class DevicePolicy(NamedTuple):
    name: str
    init: Callable
    decide: Callable
    update: Callable


def _no_update(state, batch, actions, rewards, mask):
    return state


def random_policy(num_actions: int) -> DevicePolicy:
    """Uniform over the pool, one fold of the scan key per slice."""

    def init(key):
        return ()

    def decide(state, key, batch):
        B = batch["x_emb"].shape[0]
        return jax.random.randint(key, (B,), 0, num_actions, jnp.int32)

    return DevicePolicy("random", init, decide, _no_update)


def fixed_policy(action: int, name: str = "fixed") -> DevicePolicy:
    """min-cost / max-quality: a fixed arm chosen from dataset statistics."""

    def init(key):
        return ()

    def decide(state, key, batch):
        B = batch["x_emb"].shape[0]
        return jnp.full((B,), action, jnp.int32)

    return DevicePolicy(name, init, decide, _no_update)


def greedy_policy(num_actions: int) -> DevicePolicy:
    """Context-free empirical-mean greedy (= core.baselines.EmpiricalGreedy).

    State is (sum_r, cnt) per arm; a slice's update is one masked one-hot
    matmul instead of a per-sample scatter loop.
    """

    def init(key):
        return (jnp.zeros((num_actions,), jnp.float32),
                jnp.zeros((num_actions,), jnp.float32))

    def decide(state, key, batch):
        sum_r, cnt = state
        mean_r = sum_r / jnp.maximum(cnt, 1.0)
        a = jnp.argmax(mean_r)          # ties -> lowest index, as np.argmax
        B = batch["x_emb"].shape[0]
        return jnp.full((B,), a, jnp.int32)

    def update(state, batch, actions, rewards, mask):
        sum_r, cnt = state
        onehot = jax.nn.one_hot(actions, num_actions, dtype=jnp.float32)
        onehot = onehot * mask[:, None]
        return (sum_r + onehot.T @ rewards, cnt + onehot.sum(axis=0))

    return DevicePolicy("greedy", init, decide, update)
