"""Stateless device baseline policies (DESIGN.md §8.2).

Each baseline is a triple of pure functions over an explicit state pytree,
so a full protocol run is one ``lax.scan`` and a multi-seed sweep is one
``vmap`` over PRNG keys — no Python objects, no host RNG:

    init(key)                          -> state
    decide(state, key, batch)          -> actions (S,) i32
    update(state, batch, a, r, mask)   -> state

``batch`` is the per-slice gather from :class:`DeviceReplayEnv` (x_emb,
x_feat, domain — context only; feedback stays in the engine). Semantics
mirror the host classes in ``repro.core.baselines``: greedy here is
bit-compatible with ``EmpiricalGreedy`` (decide from pre-slice statistics,
ties to the lowest index); random draws from the jax PRNG instead of
numpy's, so it matches the host loop in distribution, not samples.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, NamedTuple

import jax
import jax.numpy as jnp


class DevicePolicy(NamedTuple):
    name: str
    init: Callable
    decide: Callable
    update: Callable


class NeuralUCBState(NamedTuple):
    """Everything Algorithm 1 mutates across slices, as one explicit pytree
    (DESIGN.md §8.4) — the carry of the single-dispatch protocol scan, and
    the state snapshot the host-stepped runner threads between jit calls.
    """

    params: Dict[str, Any]      # UtilityNet weights
    opt: Dict[str, Any]         # AdamW moments
    ainv: jnp.ndarray           # shared inverse covariance (F, F)
    bufs: Dict[str, jnp.ndarray]  # (T, S) replay outcome buffers
    key: jnp.ndarray            # PRNG stream (network init already split off)


class NeuralUCBHypers(NamedTuple):
    """Per-run scalar hyperparameters, grouped so the sweep harness can
    ``vmap`` one leading grid axis over all of them at once. A negative
    ``cost_lambda`` is the sentinel for "keep the env's precomputed reward
    table" (the replay tables carry normalized cost so reward can be
    re-derived per Eq. 1 for any positive lambda on device)."""

    beta: jnp.ndarray           # UCB exploration scale
    tau_g: jnp.ndarray          # gate threshold
    gate_margin: jnp.ndarray    # gate-label margin
    lr: jnp.ndarray             # AdamW learning rate
    ridge_lambda0: jnp.ndarray  # A = lambda0 I + ... ridge
    cost_lambda: jnp.ndarray    # reward trade-off; < 0 -> env's table


class ForgettingConfig(NamedTuple):
    """Non-stationarity adaptivity knobs (DESIGN.md §9.2). A plain
    hashable NamedTuple of Python scalars so it rides through jit as a
    STATIC argument: the vanilla config compiles to exactly the
    stationary code path (bit-exact with PR-2), and each non-vanilla
    combination is its own trace.

    * ``gamma`` — per-slice discount on the A^-1 rebuild weights:
      A_t = lambda0 I + sum_s gamma^(t-s) sum_{i in s} w_i g_i g_i^T.
      1.0 = vanilla (infinite memory).
    * ``window`` — sliding window in slices: only the last ``window``
      slices enter the rebuild. 0 = off. Composes with ``gamma``.
    * ``replay_rho`` — recency weight for replay sampling: slice s is
      drawn with probability proportional to size_s * rho^(t-s) (then
      uniform within the slice), so the UtilityNet relearns drifted
      rewards instead of averaging over stale ones. 1.0 = uniform.
    """

    gamma: float = 1.0
    window: int = 0
    replay_rho: float = 1.0

    @property
    def is_vanilla(self) -> bool:
        return (self.gamma >= 1.0 and self.window == 0
                and self.replay_rho >= 1.0)


VANILLA_FORGETTING = ForgettingConfig()


def _no_update(state, batch, actions, rewards, mask):
    return state


def random_policy(num_actions: int) -> DevicePolicy:
    """Uniform over the pool, one fold of the scan key per slice."""

    def init(key):
        return ()

    def decide(state, key, batch):
        B = batch["x_emb"].shape[0]
        return jax.random.randint(key, (B,), 0, num_actions, jnp.int32)

    return DevicePolicy("random", init, decide, _no_update)


def fixed_policy(action: int, name: str = "fixed") -> DevicePolicy:
    """min-cost / max-quality: a fixed arm chosen from dataset statistics."""

    def init(key):
        return ()

    def decide(state, key, batch):
        B = batch["x_emb"].shape[0]
        return jnp.full((B,), action, jnp.int32)

    return DevicePolicy(name, init, decide, _no_update)


def greedy_policy(num_actions: int) -> DevicePolicy:
    """Context-free empirical-mean greedy (= core.baselines.EmpiricalGreedy).

    State is (sum_r, cnt) per arm; a slice's update is one masked one-hot
    matmul instead of a per-sample scatter loop.
    """

    def init(key):
        return (jnp.zeros((num_actions,), jnp.float32),
                jnp.zeros((num_actions,), jnp.float32))

    def decide(state, key, batch):
        sum_r, cnt = state
        mean_r = sum_r / jnp.maximum(cnt, 1.0)
        a = jnp.argmax(mean_r)          # ties -> lowest index, as np.argmax
        B = batch["x_emb"].shape[0]
        return jnp.full((B,), a, jnp.int32)

    def update(state, batch, actions, rewards, mask):
        sum_r, cnt = state
        onehot = jax.nn.one_hot(actions, num_actions, dtype=jnp.float32)
        onehot = onehot * mask[:, None]
        return (sum_r + onehot.T @ rewards, cnt + onehot.sum(axis=0))

    return DevicePolicy("greedy", init, decide, update)
