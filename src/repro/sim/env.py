"""Device-resident RouterBench replay environment (DESIGN.md §8.1).

Wraps the host-side :class:`repro.data.routerbench.RouterBenchSim` tables
as jnp arrays plus a padded slice-index matrix so a whole protocol run can
be expressed as a ``lax.scan`` over slices with zero host transfers. The
slice permutation is taken verbatim from the host env, so both runners
replay the *identical* stream — the parity anchor for
tests/test_sim_engine.py.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import jax.numpy as jnp
import numpy as np

from repro.data.routerbench import RouterBenchSim


@dataclasses.dataclass(frozen=True)
class DeviceReplayEnv:
    """Replay tables on device.

    idx / mask are (T, S) with S = max slice length; padded entries carry
    idx 0 and mask 0 and are excluded from every metric and update.
    """

    x_emb: jnp.ndarray      # (n, E) f32
    x_feat: jnp.ndarray     # (n, F) f32
    domain: jnp.ndarray     # (n,)   i32
    quality: jnp.ndarray    # (n, K) f32
    cost: jnp.ndarray       # (n, K) f32
    reward: jnp.ndarray     # (n, K) f32
    idx: jnp.ndarray        # (T, S) i32
    mask: jnp.ndarray       # (T, S) f32
    # Eq.-1 parameters of the precomputed reward table, carried so the
    # scenario engine can re-derive per-slice rewards for transformed
    # quality/cost tables on device (repro.sim.scenarios).
    cost_lambda: float = 1.0

    @property
    def n(self) -> int:
        return self.x_emb.shape[0]

    @property
    def K(self) -> int:
        return self.quality.shape[1]

    @property
    def n_slices(self) -> int:
        return self.idx.shape[0]

    @property
    def slice_width(self) -> int:
        return self.idx.shape[1]

    @property
    def slice_sizes(self) -> np.ndarray:
        return np.asarray(self.mask.sum(axis=1)).astype(np.int64)

    def slice_xs(self) -> Dict[str, jnp.ndarray]:
        """Per-slice scan inputs: slice number, index rows, masks. The
        slice number feeds the scenario engine's per-slice transforms
        (identity when no scenario is active)."""
        return {"t": jnp.arange(self.n_slices, dtype=jnp.int32),
                "idx": self.idx, "mask": self.mask}

    # arm statistics (match RouterBenchSim's convenience methods) ----------
    def min_cost_action(self) -> int:
        return int(jnp.argmin(self.cost.mean(axis=0)))

    def max_quality_action(self) -> int:
        return int(jnp.argmax(self.quality.mean(axis=0)))

    @classmethod
    def from_host(cls, env: RouterBenchSim) -> "DeviceReplayEnv":
        """Lift a host RouterBenchSim (tables + its slice permutation)."""
        T = env.n_slices
        S = max(len(s) for s in env.slices)
        idx = np.zeros((T, S), np.int32)
        mask = np.zeros((T, S), np.float32)
        for t, sl in enumerate(env.slices):
            idx[t, :len(sl)] = sl
            mask[t, :len(sl)] = 1.0
        return cls(
            x_emb=jnp.asarray(env.x_emb, jnp.float32),
            x_feat=jnp.asarray(env.data["x_feat"], jnp.float32),
            domain=jnp.asarray(env.data["domain"], jnp.int32),
            quality=jnp.asarray(env.data["quality"], jnp.float32),
            cost=jnp.asarray(env.data["cost"], jnp.float32),
            reward=jnp.asarray(env.reward_table, jnp.float32),
            idx=jnp.asarray(idx),
            mask=jnp.asarray(mask),
            cost_lambda=float(env.cost_lambda),
        )
