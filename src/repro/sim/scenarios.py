"""Declarative non-stationary scenario engine (DESIGN.md §9).

A :class:`Scenario` is a pure description of how the replay environment
drifts over the protocol run: per-slice, per-arm transforms of the cost
and quality tables, per-slice arm availability, a re-sliced query stream
(domain-mix shift), and a fixed feedback delay. Scenarios are *compiled*
once on the host into a :class:`ScenarioTables` pytree of (T, K) arrays
that the engine scans alongside the slice stream — every scenario run is
still ONE device dispatch (`repro.sim.engine`), and because all scenarios
share the same pytree shapes they also share one compiled trace (only a
distinct ``feedback_delay`` retraces).

Slice-t effective tables (engine's `_effective_slice`):

    quality_t = clip(quality * quality_mult[t] + quality_add[t], 0, 1)
    cost_t    = cost * cost_mult[t]
    reward_t  = quality_t * exp(-lambda * log1p(cost_t) / log1p(C_max))

with C_max and lambda frozen at the env's stationary values so reward
scales stay comparable across slices (a shocked price can push the
normalized cost past 1 — deliberately: that is what a price shock does to
a fixed operating point). ``avail[t, a] = 0`` marks arm ``a`` as
*announced* unavailable (deprecation / pre-launch): the router cannot
select it and the dynamic oracle excludes it. Unannounced failures are
modeled through quality instead (see ``arm_outage``).

The registry maps names to builder functions taking the
:class:`DeviceReplayEnv` (for arm statistics and stream shape); use
:func:`register_scenario` to add more.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, NamedTuple, Optional, Tuple, Union

import jax.numpy as jnp
import numpy as np

from repro.sim.env import DeviceReplayEnv


class ScenarioTables(NamedTuple):
    """Compiled per-slice transforms, all (T, K) float32 — the pytree the
    protocol scan consumes (row t drives slice t)."""

    cost_mult: jnp.ndarray
    quality_mult: jnp.ndarray
    quality_add: jnp.ndarray
    avail: jnp.ndarray


@dataclasses.dataclass(frozen=True)
class Scenario:
    """A compiled scenario: table transforms (None = stationary fast
    path), an optional re-sliced stream (domain-mix shift), and a fixed
    feedback delay in slices (outcomes of slice t become learnable at
    slice t + delay; metrics still accrue at t)."""

    name: str
    tables: Optional[ScenarioTables] = None
    feedback_delay: int = 0
    stream: Optional[Tuple[np.ndarray, np.ndarray]] = None  # (idx, mask)


SCENARIOS: Dict[str, Callable[[DeviceReplayEnv], Scenario]] = {}


def register_scenario(name: str):
    def deco(fn: Callable[[DeviceReplayEnv], Scenario]):
        SCENARIOS[name] = fn
        return fn
    return deco


def make_scenario(env: DeviceReplayEnv,
                  name: str) -> Scenario:
    if name not in SCENARIOS:
        raise KeyError(f"unknown scenario {name!r}; registered: "
                       f"{sorted(SCENARIOS)}")
    return SCENARIOS[name](env)


def resolve_scenario(env: DeviceReplayEnv,
                     scenario: Union[None, str, Scenario]
                     ) -> Tuple[DeviceReplayEnv, Optional[ScenarioTables],
                                int]:
    """Resolve a scenario argument (name | Scenario | None) into the
    (possibly re-sliced) env, the transform pytree (None = stationary
    fast path), and the static feedback delay."""
    if scenario is None:
        return env, None, 0
    if isinstance(scenario, str):
        scenario = make_scenario(env, scenario)
    if scenario.tables is not None:
        # every slice must keep >= 1 selectable arm: with none, the
        # masked warm draw would emit the out-of-range action K and the
        # slice's samples would silently vanish from the histograms
        av = np.asarray(scenario.tables.avail)
        if (av.max(axis=1) <= 0).any():
            bad = int(np.argmax(av.max(axis=1) <= 0))
            raise ValueError(
                f"scenario {scenario.name!r}: slice {bad} has no "
                f"available arm (avail row is all zero)")
    if scenario.stream is not None:
        idx, mask = scenario.stream
        env = dataclasses.replace(env, idx=jnp.asarray(idx, jnp.int32),
                                  mask=jnp.asarray(mask, jnp.float32))
    return env, scenario.tables, int(scenario.feedback_delay)


# ------------------------------------------------------------- builders --
def identity_transforms(T: int, K: int) -> Dict[str, np.ndarray]:
    """Host-side identity transform arrays for builders to edit in place."""
    return {"cost_mult": np.ones((T, K), np.float32),
            "quality_mult": np.ones((T, K), np.float32),
            "quality_add": np.zeros((T, K), np.float32),
            "avail": np.ones((T, K), np.float32)}


def tables_from(tr: Dict[str, np.ndarray]) -> ScenarioTables:
    return ScenarioTables(**{k: jnp.asarray(v) for k, v in tr.items()})


def identity_tables(T: int, K: int) -> ScenarioTables:
    """An explicit no-op ScenarioTables — exercises the scenario code
    path while describing the stationary environment (tests use this to
    pin the transform path against the fast path)."""
    return tables_from(identity_transforms(T, K))


def _strong_arm(env: DeviceReplayEnv) -> int:
    """The arm a stationary learner converges to: best mean reward."""
    return int(np.asarray(env.reward).mean(axis=0).argmax())


def _ramp(T: int, t0: int, v0: float, v1: float) -> np.ndarray:
    """(T,) schedule: v0 before t0, then geometric ramp to v1 at T-1."""
    out = np.full((T,), v0, np.float64)
    span = max(T - 1 - t0, 1)
    for t in range(t0, T):
        out[t] = v0 * (v1 / v0) ** ((t - t0) / span)
    return out.astype(np.float32)


@register_scenario("stationary")
def _stationary(env: DeviceReplayEnv) -> Scenario:
    """The paper's setting: no drift. Compiles to the fast path (no
    transform pytree), so `run_neuralucb_device` / `run_baseline_device`
    with scenario="stationary" are byte-identical to scenario-free
    calls. (`run_protocol_device` is the one exception: naming ANY
    scenario there selects the scanned fixed-schedule NeuralUCB runner —
    see its docstring.)"""
    return Scenario("stationary")


@register_scenario("price_shock")
def _price_shock(env: DeviceReplayEnv) -> Scenario:
    """Rolling provider repricing: three waves of 60x price jumps, each
    landing on the next tier of the pool's best arms — i.e. on the arms
    a learner that adapted to the previous wave is now routing to. A
    learner that keeps averaging over pre-shock feedback pays the
    adaptation lag at every wave."""
    T, K = env.n_slices, env.K
    tr = identity_transforms(T, K)
    order = np.asarray(env.reward).mean(axis=0).argsort()
    waves = [order[-3:], order[-6:-3], order[-9:-6]]
    starts = [max(1, T // 4), max(2, T // 2), max(3, (3 * T) // 4)]
    for arms, s in zip(waves, starts):
        if s < T and len(arms):
            tr["cost_mult"][s:, arms] = 60.0
    return Scenario("price_shock", tables_from(tr))


@register_scenario("cost_drift")
def _cost_drift(env: DeviceReplayEnv) -> Scenario:
    """Smooth market rotation: the priciest third of the pool gets 60%
    cheaper by the end of the run, the cheapest third 5x pricier —
    the cost/quality frontier slowly inverts."""
    T, K = env.n_slices, env.K
    tr = identity_transforms(T, K)
    rank = np.argsort(np.asarray(env.cost).mean(axis=0))
    third = max(1, K // 3)      # K < 3: rank[-0:] would grab EVERY arm
    lo, hi = rank[:third], rank[-third:]
    for a in lo:
        tr["cost_mult"][:, a] = _ramp(T, 1, 1.0, 5.0)
    for a in hi:
        tr["cost_mult"][:, a] = _ramp(T, 1, 1.0, 0.4)
    return Scenario("cost_drift", tables_from(tr))


@register_scenario("quality_decay")
def _quality_decay(env: DeviceReplayEnv) -> Scenario:
    """The strongest arm's quality decays to 15% over the run (model
    staleness / silent degradation) — selectable throughout."""
    T, K = env.n_slices, env.K
    tr = identity_transforms(T, K)
    tr["quality_mult"][:, _strong_arm(env)] = _ramp(T, 1, 1.0, 0.15)
    return Scenario("quality_decay", tables_from(tr))


@register_scenario("arm_outage")
def _arm_outage(env: DeviceReplayEnv) -> Scenario:
    """Cascading UNANNOUNCED outage: the top reward tier starts
    returning garbage (quality 0) a third of the way in, and the tier
    the router fails over to follows at two thirds. The arms stay
    selectable — only feedback reveals the failure — so stale replay
    keeps steering traffic into dead arms."""
    T, K = env.n_slices, env.K
    tr = identity_transforms(T, K)
    order = np.asarray(env.reward).mean(axis=0).argsort()
    tr["quality_mult"][max(1, T // 3):, order[-3:]] = 0.0
    if len(order[-6:-3]):
        tr["quality_mult"][max(2, (2 * T) // 3):, order[-6:-3]] = 0.0
    return Scenario("arm_outage", tables_from(tr))


@register_scenario("arm_arrival")
def _arm_arrival(env: DeviceReplayEnv) -> Scenario:
    """ANNOUNCED mid-stream launch: the strongest arm does not exist for
    the first half of the run (avail 0 — not selectable, excluded from
    the dynamic oracle), then ships."""
    T, K = env.n_slices, env.K
    tr = identity_transforms(T, K)
    tr["avail"][:max(1, T // 2), _strong_arm(env)] = 0.0
    return Scenario("arm_arrival", tables_from(tr))


@register_scenario("domain_shift")
def _domain_shift(env: DeviceReplayEnv) -> Scenario:
    """Query-mix shift: the same samples, re-sliced in domain order, so
    early slices are one task mix and late slices another (slice sizes
    preserved; a pure stream transform, no table drift)."""
    idx = np.asarray(env.idx)
    mask = np.asarray(env.mask)
    ids = idx[mask > 0]                          # stream order, row-major
    dom = np.asarray(env.domain)[ids]
    ids = ids[np.argsort(dom, kind="stable")]
    new_idx = np.zeros_like(idx)
    pos = 0
    for t in range(idx.shape[0]):
        n_t = int(mask[t].sum())
        new_idx[t, :n_t] = ids[pos:pos + n_t]
        pos += n_t
    return Scenario("domain_shift", stream=(new_idx, mask))


@register_scenario("delayed_feedback")
def _delayed_feedback(env: DeviceReplayEnv) -> Scenario:
    """Fixed-delay feedback: slice-t outcomes become learnable at slice
    t+2 (grading latency). Rewards still accrue at t; only the
    learner's visibility lags."""
    return Scenario("delayed_feedback", feedback_delay=2)
