"""Device-resident online routing protocol engine (DESIGN.md §8–§10).

The seed implementation (`repro.core.protocol.run_protocol`) drives the
paper's Algorithm 1 as a host Python loop with a device round-trip per
slice per policy and per-minibatch host transfers; this package keeps the
whole replay environment (quality / cost / reward tables) resident on the
accelerator and runs every policy — NeuralUCB, LinUCB, NeuralTS,
ε-greedy, Boltzmann, and the stateless baselines — through ONE generic
protocol scan over a :class:`BanditPolicy` pytree-of-callables
(`run_policy_device`), with (policy × hypers × seed) studies flattened
into sharded lane vmaps executed as a single dispatch
(`run_policy_sweep`). Scenarios (DESIGN.md §9), `ForgettingConfig`
adaptivity, delayed feedback, and availability fallback thread through
every policy automatically.
"""
from repro.sim.env import DeviceReplayEnv
from repro.sim.policies import (
    OPE_SMOOTHING_EPS,
    POLICIES,
    VANILLA_FORGETTING,
    BanditPolicy,
    DevicePolicy,
    ForgettingConfig,
    LinUCBHypers,
    MFHypers,
    NeuralPolicyHypers,
    NeuralUCBHypers,
    NeuralUCBState,
    PolicyCtx,
    SupervisedHypers,
    as_bandit_policy,
    boltzmann_policy,
    dyn_min_cost_policy,
    eps_greedy_policy,
    fixed_policy,
    greedy_policy,
    linucb_policy,
    make_policy,
    neural_ts_policy,
    neuralucb_policy,
    random_policy,
    register_policy,
    sup_mf_policy,
    sup_winrate_policy,
)
from repro.sim.scenarios import (
    SCENARIOS,
    Scenario,
    ScenarioTables,
    identity_tables,
    make_scenario,
    register_scenario,
    resolve_scenario,
)
from repro.sim.engine import (
    DeviceNeuralUCB,
    neuralucb_train_schedule,
    pretrain_policy_state,
    run_baseline_device,
    run_baseline_sweep,
    run_neuralucb_device,
    run_neuralucb_sweep,
    run_policy_device,
    run_policy_sweep,
    run_protocol_device,
    sweep_point_results,
)

__all__ = [
    "DeviceReplayEnv",
    "BanditPolicy",
    "DevicePolicy",
    "PolicyCtx",
    "POLICIES",
    "OPE_SMOOTHING_EPS",
    "ForgettingConfig",
    "VANILLA_FORGETTING",
    "LinUCBHypers",
    "MFHypers",
    "SupervisedHypers",
    "NeuralPolicyHypers",
    "NeuralUCBHypers",
    "NeuralUCBState",
    "SCENARIOS",
    "Scenario",
    "ScenarioTables",
    "identity_tables",
    "make_scenario",
    "register_scenario",
    "resolve_scenario",
    "as_bandit_policy",
    "boltzmann_policy",
    "dyn_min_cost_policy",
    "eps_greedy_policy",
    "fixed_policy",
    "greedy_policy",
    "linucb_policy",
    "make_policy",
    "neural_ts_policy",
    "neuralucb_policy",
    "random_policy",
    "register_policy",
    "sup_mf_policy",
    "sup_winrate_policy",
    "DeviceNeuralUCB",
    "neuralucb_train_schedule",
    "pretrain_policy_state",
    "run_baseline_device",
    "run_baseline_sweep",
    "run_neuralucb_device",
    "run_neuralucb_sweep",
    "run_policy_device",
    "run_policy_sweep",
    "run_protocol_device",
    "sweep_point_results",
]
