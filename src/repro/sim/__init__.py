"""Device-resident online routing protocol engine (DESIGN.md §8).

The seed implementation (`repro.core.protocol.run_protocol`) drives the
paper's Algorithm 1 as a host Python loop with a device round-trip per
slice per policy and per-minibatch host transfers; this package keeps the
whole replay environment (quality / cost / reward tables) resident on the
accelerator and runs each slice's DECIDE → feedback-lookup → UPDATE as a
single fused jit call. Baselines become stateless jnp policies swept over
seeds with vmap, a full T-slice baseline run is one lax.scan, and the
whole NeuralUCB Algorithm-1 run is one scanned dispatch
(`run_neuralucb_device`) with seed/β sweeps as one vmapped, device-sharded
dispatch (`run_neuralucb_sweep`, DESIGN.md §8.4).
"""
from repro.sim.env import DeviceReplayEnv
from repro.sim.policies import (
    VANILLA_FORGETTING,
    DevicePolicy,
    ForgettingConfig,
    NeuralUCBHypers,
    NeuralUCBState,
    fixed_policy,
    greedy_policy,
    random_policy,
)
from repro.sim.scenarios import (
    SCENARIOS,
    Scenario,
    ScenarioTables,
    identity_tables,
    make_scenario,
    register_scenario,
    resolve_scenario,
)
from repro.sim.engine import (
    DeviceNeuralUCB,
    neuralucb_train_schedule,
    run_baseline_device,
    run_baseline_sweep,
    run_neuralucb_device,
    run_neuralucb_sweep,
    run_protocol_device,
    sweep_point_results,
)

__all__ = [
    "DeviceReplayEnv",
    "DevicePolicy",
    "ForgettingConfig",
    "VANILLA_FORGETTING",
    "NeuralUCBHypers",
    "NeuralUCBState",
    "SCENARIOS",
    "Scenario",
    "ScenarioTables",
    "identity_tables",
    "make_scenario",
    "register_scenario",
    "resolve_scenario",
    "fixed_policy",
    "greedy_policy",
    "random_policy",
    "DeviceNeuralUCB",
    "neuralucb_train_schedule",
    "run_baseline_device",
    "run_baseline_sweep",
    "run_neuralucb_device",
    "run_neuralucb_sweep",
    "run_protocol_device",
    "sweep_point_results",
]
