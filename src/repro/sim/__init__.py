"""Device-resident online routing protocol engine (DESIGN.md §8).

The seed implementation (`repro.core.protocol.run_protocol`) drives the
paper's Algorithm 1 as a host Python loop with a device round-trip per
slice per policy and per-minibatch host transfers; this package keeps the
whole replay environment (quality / cost / reward tables) resident on the
accelerator and runs each slice's DECIDE → feedback-lookup → UPDATE as a
single fused jit call. Baselines become stateless jnp policies swept over
seeds with vmap, and a full T-slice baseline run is one lax.scan.
"""
from repro.sim.env import DeviceReplayEnv
from repro.sim.policies import (
    DevicePolicy,
    fixed_policy,
    greedy_policy,
    random_policy,
)
from repro.sim.engine import (
    DeviceNeuralUCB,
    run_baseline_device,
    run_baseline_sweep,
    run_protocol_device,
)

__all__ = [
    "DeviceReplayEnv",
    "DevicePolicy",
    "fixed_policy",
    "greedy_policy",
    "random_policy",
    "DeviceNeuralUCB",
    "run_baseline_device",
    "run_baseline_sweep",
    "run_protocol_device",
]
